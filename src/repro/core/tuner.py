"""Algorithm 3 — SoC-Tuner(X, T, n, u, b, v_th): the full exploration loop.

Operates over a finite candidate *pool* (the paper's experiments sample 2500
design points and treat their flow metrics as the metric space); the flow is
any callable ``idx [k,d] -> y [k,m]`` — the bundled VLSI-flow surrogate, the
simplified analytical model, or a real flow runner.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .engine import BOEngine
from .icd import icd_from_data
from .pareto import adrs, pareto_mask
from .propose import (PROPOSER_FOLD, ProposerConfig, ProposerStats,
                      propose_and_replace)
from .sampling import soc_init, transform_to_icd
from .space import DesignSpace

__all__ = ["TunerResult", "soc_tuner", "frontier_subset_rows",
           "explore_prologue"]

FlowFn = Callable[[np.ndarray], np.ndarray]


def icd_trial_rows(key: jax.Array, n_pool: int, n: int
                   ) -> tuple[np.ndarray, jax.Array]:
    """Alg. 3 line 1 setup: draw the ``n`` ICD trial pool-rows and return
    them with the advanced key. Shared with the fleet runner so both paths
    consume the PRNG stream identically."""
    k_icd, _k_init, key = jax.random.split(key, 3)
    rows = np.asarray(jax.random.choice(
        k_icd, n_pool, shape=(min(n, n_pool),), replace=False))
    return rows, key


def merge_trial_evals(evaluated: "list[int]", y_init: np.ndarray,
                      trial_rows: np.ndarray, trial_y: np.ndarray,
                      reuse_icd_trials: bool) -> tuple["list[int]", np.ndarray]:
    """Alg. 3 line 4 bookkeeping: seed the GP with the TED-init evaluations
    plus (optionally) the ICD trial evaluations not already covered. Shared
    with the fleet runner — the evaluation order defines the trajectory."""
    y_list = [np.asarray(y_init)]
    if reuse_icd_trials:
        seen = set(evaluated)  # built ONCE, not per element
        fresh, keep = [], []
        for i, r in enumerate(trial_rows):
            r = int(r)
            if r not in seen:
                seen.add(r)
                fresh.append(r)
                keep.append(i)
        evaluated = evaluated + fresh
        y_list.append(np.asarray(trial_y)[keep])
    return evaluated, np.concatenate(y_list, axis=0)


def round_record(y: np.ndarray, n_evaluated: int, round_i: int,
                 reference_front: np.ndarray | None,
                 wall_s: float | None = None) -> dict:
    """One history entry for round ``round_i``.

    Shared with the fleet runner so sequential and fleet histories always
    carry the same keys (fig7 reads them interchangeably). ``wall_s``
    (optional) records the round's wall time — ``engine_bench`` reads it."""
    front = _front(y)
    rec = {"round": round_i, "evaluations": n_evaluated,
           "pareto_size": int(front.sum())}
    if reference_front is not None:
        rec["adrs"] = adrs(reference_front, y[front])
    if wall_s is not None:
        rec["wall_s"] = wall_s
    return rec


def frontier_subset_rows(key: jax.Array, n_pool: int,
                         frontier_subset: int) -> np.ndarray | None:
    """Rows used for the O(q³) joint frontier sampling, or ``None`` for the
    whole pool. Shared by the sequential loop and the fleet runner so a
    fleet-of-one draws the exact same subset as ``soc_tuner``."""
    if n_pool > frontier_subset:
        return np.asarray(jax.random.choice(
            key, n_pool, shape=(frontier_subset,), replace=False))
    return None


def explore_prologue(space: DesignSpace, pool_idx: np.ndarray, flow: FlowFn,
                     key: jax.Array, *, n: int, mu: float, b: int,
                     v_th: float, use_kernels: bool = False,
                     reuse_icd_trials: bool = True):
    """Algorithm 3 lines 1-4: ICD trials → importance → prune/TED-init →
    seed evaluations. Returns ``(key, v, pruned, pool_icd, evaluated, y)``.

    Shared between :func:`soc_tuner` and the exploration service
    (``repro.service.runner``) — operation-for-operation the historical
    prologue, so both drivers consume the PRNG stream and the flow budget
    identically. A checkpoint resume replays everything after the flow
    calls from the stored ``v`` instead (see :func:`_prologue_from_v`).
    """
    N = pool_idx.shape[0]
    # Line 1: v = ICD(X, n). Trials are drawn from the pool so their metrics
    # can seed the GP (the paper's flow budget accounting does the same: the
    # n importance trials are real evaluations).
    trial_rows, key = icd_trial_rows(key, N, n)
    trial_y = np.asarray(flow(pool_idx[trial_rows]))
    v = icd_from_data(space, pool_idx[trial_rows], trial_y)

    # Line 2: Z = SoC-Init(X, µ, b, v, v_th)  (prune + ICD transform + TED)
    init_rows, pruned, pool_icd = soc_init(
        space, pool_idx, v, v_th=v_th, b=b, mu=mu, use_kernel=use_kernels)
    pool_icd = jnp.asarray(pool_icd, jnp.float32)

    # Line 4: y <- VLSIFlow(Z)
    evaluated: list[int] = list(dict.fromkeys(int(r) for r in init_rows))
    y_init = np.asarray(flow(pool_idx[np.asarray(evaluated)]))
    evaluated, y = merge_trial_evals(evaluated, y_init, trial_rows, trial_y,
                                     reuse_icd_trials)
    return key, v, pruned, pool_icd, evaluated, y


def _prologue_from_v(space: DesignSpace, pool_idx: np.ndarray, v: np.ndarray,
                     *, mu: float, b: int, v_th: float,
                     use_kernels: bool = False):
    """Rebuild the flow-free prologue outputs from a checkpointed importance
    vector: ``soc_init`` is deterministic in ``(space, pool, v)``, so resume
    never re-pays the trial/init flow evaluations."""
    _, pruned, pool_icd = soc_init(space, pool_idx, v, v_th=v_th, b=b, mu=mu,
                                   use_kernel=use_kernels)
    return pruned, jnp.asarray(pool_icd, jnp.float32)


def _pool_fingerprint(pool_idx: np.ndarray) -> str:
    """Cheap content hash of the candidate pool — a resumed run must explore
    the identical pool or the stored engine state is meaningless."""
    import hashlib

    return hashlib.sha1(np.ascontiguousarray(
        np.asarray(pool_idx, np.int64)).tobytes()).hexdigest()


@dataclasses.dataclass
class TunerResult:
    space: DesignSpace                # pruned space actually explored
    v: np.ndarray                     # ICD importance vector (Alg. 1)
    evaluated_rows: np.ndarray        # pool-row indices, in evaluation order
    y: np.ndarray                     # metrics for evaluated rows [k, m]
    pareto_rows: np.ndarray           # subset of evaluated_rows on the front
    pareto_y: np.ndarray              # their metrics (the learned Y*)
    history: list[dict]               # per-round log (for ADRS curves)
    wall_s: float
    engine_stats: dict | None = None  # BOEngine counters (refactors, ...)

    def pareto_idx(self, pool_idx: np.ndarray) -> np.ndarray:
        """Design-point index vectors X* restored to the original space
        (Alg. 3 line 11)."""
        return np.asarray(pool_idx)[self.pareto_rows]


def _front(y: np.ndarray) -> np.ndarray:
    return np.asarray(pareto_mask(jnp.asarray(np.asarray(y, np.float64))))


def soc_tuner(
    space: DesignSpace,
    pool_idx: np.ndarray,
    flow: FlowFn,
    *,
    T: int = 40,
    n: int = 30,
    mu: float = 0.1,
    b: int = 20,
    v_th: float = 0.07,
    s_frontiers: int = 10,
    frontier_subset: int = 512,
    gp_steps: int = 150,
    key: jax.Array | None = None,
    reference_front: np.ndarray | None = None,
    reuse_icd_trials: bool = True,
    use_kernels: bool = False,
    weights: np.ndarray | None = None,
    incremental: bool = False,
    warm_start: bool | None = None,
    warm_steps: int | None = None,
    drift_tol: float = 1.0,
    pool_chunk: int | str | None = None,
    profile_stages: bool = False,
    q: int = 1,
    fantasy: str = "mean",
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    resume: bool = False,
    proposer=None,
    verbose: bool = False,
) -> TunerResult:
    """Run SoC-Tuner over ``pool_idx`` [N, d] candidate designs.

    Follows Algorithm 3 line by line; ``reference_front`` (the real Pareto
    front of the pool, if known) enables per-round ADRS logging for Fig. 7(a).
    ``weights`` [m] (optional) biases the acquisition's per-objective
    information gain (Eq. 9 scalarization) — exploration focus, not a change
    to the Pareto bookkeeping.

    The per-round surrogate work runs on a persistent :class:`BOEngine`.
    ``incremental=False`` (the fidelity default) executes the historical
    from-scratch round and reproduces the seed trajectory bit-for-bit;
    ``incremental=True`` enables warm-started fits, rank-k Cholesky updates,
    cached pool covariances and device-side selection — same math to
    numerical tolerance, measurably faster per round (see
    ``benchmarks/engine_bench.py``). ``warm_start`` (default: follow
    ``incremental``) plumbs the previous round's ``GPParams`` into ``fit_gp``
    even on the from-scratch path; ``warm_steps``/``drift_tol`` tune the
    incremental engine's fit schedule and refactorization policy.
    ``pool_chunk`` (int | ``"auto"``; requires ``incremental=True``) streams
    the engine's O(N) pool state in column chunks so ``n_pool`` can grow to
    10⁵–10⁶ candidates — identical selections at any chunk size; see
    ``docs/scaling.md``. ``profile_stages`` (requires ``incremental=True``)
    times every round stage separately and accumulates the wall seconds in
    the result's ``engine_stats["stage_wall_s"]`` (surfaced by
    ``engine_bench --profile``).

    ``q`` (requires ``incremental=True`` when > 1) selects q candidates per
    round via fantasy updates (``BOEngine.select_q``; ``fantasy`` picks the
    imputation rule) and evaluates them in ONE flow call — ``q=1`` is the
    historical one-pick round, bit-for-bit. ``checkpoint_dir`` writes a
    versioned snapshot of the full exploration state (engine, RNG key,
    history) every ``checkpoint_every`` rounds; ``resume=True`` continues a
    killed run from the latest snapshot *bit-exactly*, without re-paying any
    flow evaluation (see ``docs/service.md``).

    ``proposer`` (None | bool | dict | :class:`ProposerConfig`; default OFF,
    requires ``incremental=True``) enables the between-round perturbation
    proposer: after each round the lowest-scoring unevaluated pool columns
    are replaced by novel designs sampled near the current Pareto front
    (:mod:`repro.core.propose`). The proposer draws its randomness through
    ``jax.random.fold_in`` off the driver key, so a proposer-off run stays
    byte-identical to one without the knob; checkpoints additionally carry
    the live (edited) pool and resume bit-exactly.
    """
    t0 = time.monotonic()
    key = jax.random.PRNGKey(0) if key is None else key
    pool_idx = np.asarray(pool_idx)
    N = pool_idx.shape[0]
    pcfg = ProposerConfig.from_arg(proposer)
    pstats = ProposerStats()
    if pcfg.enabled:
        if not incremental:
            raise ValueError(
                "proposer requires incremental=True: victim scoring runs on "
                "the incremental engine's cached round state (pool_scores)")
        pool_idx = np.array(pool_idx)  # private copy — the proposer edits it
    if q < 1:
        raise ValueError(f"q must be >= 1, got {q}")
    if q > 1 and not incremental:
        raise ValueError(
            "q > 1 requires incremental=True: fantasy q-batch selection "
            "runs on the incremental engine (checked up front so no flow "
            "budget is spent on a run that cannot start)")
    # everything that defines the trajectory must survive a resume intact
    # (T may grow: it only decides when the loop stops)
    config = {"q": int(q), "n": int(n), "b": int(b), "mu": float(mu),
              "v_th": float(v_th), "gp_steps": int(gp_steps),
              "s_frontiers": int(s_frontiers),
              "frontier_subset": int(frontier_subset), "fantasy": fantasy,
              "incremental": bool(incremental), "pool_chunk": pool_chunk,
              "warm_start": warm_start, "warm_steps": warm_steps,
              "drift_tol": float(drift_tol),
              "reuse_icd_trials": bool(reuse_icd_trials),
              "weights": (None if weights is None else
                          [float(x) for x in np.asarray(weights).reshape(-1)])}
    if pcfg.enabled:
        # Only joins the trajectory guard when ON so proposer-less
        # checkpoints written before this knob existed keep resuming.
        config["proposer"] = pcfg.as_dict()
    # Fingerprint of the pool AS PASSED — the proposer edits pool_idx, but
    # a resuming caller passes the original pool, so the guard pins that.
    pool_fp = _pool_fingerprint(pool_idx)

    snap = None
    if resume and checkpoint_dir:
        from repro.service.checkpoint import load_latest_validated

        snap = load_latest_validated(
            checkpoint_dir, driver="soc_tuner", pool=pool_fp, config=config)

    if snap is None:
        key, v, pruned, pool_icd, evaluated, y = explore_prologue(
            space, pool_idx, flow, key, n=n, mu=mu, b=b, v_th=v_th,
            use_kernels=use_kernels, reuse_icd_trials=reuse_icd_trials)
    else:
        v = np.asarray(snap["v"])
        if pcfg.enabled and "pool_live" in snap:
            # Continue on the edited pool; evaluated rows are immutable so
            # every recorded pick still denotes the design it scored.
            pool_idx = np.array(snap["pool_live"])
            pstats = ProposerStats.from_dict(snap["proposer_stats"])
        pruned, pool_icd = _prologue_from_v(space, pool_idx, v, mu=mu, b=b,
                                            v_th=v_th, use_kernels=use_kernels)
        evaluated = [int(r) for r in snap["evaluated"]]
        y = np.asarray(snap["y"], np.float32)
        key = jnp.asarray(snap["key"])

    from repro.obs import log_progress  # deferred: obs imports this module

    history: list[dict] = [] if snap is None else list(snap["history"])
    t_round = time.monotonic()

    def log_round(i: int):
        nonlocal t_round
        now = time.monotonic()
        log_progress(history, y, len(evaluated), i, reference_front,
                     verbose=verbose, tag="soc-tuner",
                     wall_s=now - t_round)
        t_round = now

    start_round = 0 if snap is None else int(snap["round"])
    if snap is None:
        log_round(0)

    # Lines 5-10: BO loop, run on a persistent device-resident engine. The
    # engine internally negates targets (paper metrics are minimized, MES
    # maximizes) and owns the never-re-evaluate mask + argmax (Line 7).
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    engine = BOEngine(pool_icd, incremental=incremental,
                      warm_start=warm_start, gp_steps=gp_steps,
                      warm_steps=warm_steps, drift_tol=drift_tol,
                      s_frontiers=s_frontiers, weights=w,
                      pool_chunk=pool_chunk,
                      profile_stages=profile_stages)
    if snap is None:
        engine.observe(evaluated, y)
    else:
        engine.load_state_dict(snap["engine"])

    def save_checkpoint(round_i: int) -> None:
        from repro.service.checkpoint import (prune_snapshots, save_snapshot,
                                              snapshot_path)

        d = {
            "driver": "soc_tuner", "round": round_i,
            "pool": pool_fp, "config": config,
            "key": np.asarray(key), "v": np.asarray(v),
            "evaluated": np.asarray(evaluated, np.int64), "y": y,
            "history": history, "engine": engine.state_dict()}
        if pcfg.enabled:
            d["pool_live"] = np.asarray(pool_idx)
            d["proposer_stats"] = pstats.as_dict()
        save_snapshot(snapshot_path(checkpoint_dir, round_i), d)
        prune_snapshots(checkpoint_dir)

    for it in range(start_round, T):
        key, k_fit, k_acq, k_sub = jax.random.split(key, 4)
        del k_fit  # reserved slot — keeps the key schedule seed-stable

        # Frontier sampling over a subset (O(q³) Cholesky), scoring over all.
        sub = frontier_subset_rows(k_sub, N, frontier_subset)
        picks = engine.select_q(k_acq, q, sub_rows=sub, fantasy=fantasy)

        # Line 8: evaluate and append (one flow call for the whole batch).
        y_new = np.asarray(flow(pool_idx[np.asarray(picks)]))
        evaluated.extend(picks)
        y = np.concatenate([y, y_new], axis=0)
        engine.observe(picks, y_new)
        log_round(it + 1)
        # Between-round proposal (default off): refresh the weakest pool
        # columns before the next round spends acquisition budget on them.
        # fold_in keys it off the carried key WITHOUT advancing the split
        # schedule, and runs before the checkpoint so a killed run resumes
        # on exactly the pool the next round would have seen. Runs after the
        # final round too — T may grow across resumes, so the proposal
        # schedule must not depend on it.
        if pcfg.enabled and (it + 1) % pcfg.every == 0:
            out = propose_and_replace(
                engine, space, jax.random.fold_in(key, PROPOSER_FOLD + it),
                pool_idx, cfg=pcfg,
                encode_cols=lambda c: transform_to_icd(
                    space, pruned.apply_pins(jnp.asarray(c)), v),
                evaluated=[evaluated], ys=[y], stats=pstats)
            if out is not None:
                pool_idx[out.victims] = out.new_idx
        if checkpoint_dir and (it + 1) % checkpoint_every == 0:
            save_checkpoint(it + 1)

    front = _front(y)
    rows = np.asarray(evaluated)
    stats_d = engine.stats.as_dict()
    if pcfg.enabled:
        stats_d["proposer"] = pstats.as_dict()
    return TunerResult(
        space=pruned, v=np.asarray(v), evaluated_rows=rows, y=y,
        pareto_rows=rows[front], pareto_y=y[front], history=history,
        wall_s=time.monotonic() - t0, engine_stats=stats_d)
