"""IMOO — information-gain multi-objective acquisition (paper Eqs. 5-11).

The paper's Eq. (7) approximates the information gain about the Pareto set by
Monte-Carlo over S sampled Pareto frontiers Y*_s; treating each objective as a
truncated Gaussian bounded by the frontier maximum gives the MES-style closed
form of Eq. (8):

    AF(i, x') = Σ_s [ γ_s^i(x')·φ(γ_s^i) / (2·Φ(γ_s^i)) − ln Φ(γ_s^i) ]
    γ_s^i(x') = (y*_{s,i} − µ_i(x')) / σ_i(x')
    I(x')     = Σ_i AF(i, x')

(φ = standard normal pdf, Φ = cdf; the paper's Eq. 8 swaps the symbol names —
see DESIGN.md fidelity notes. Likewise Eq. (10) prints argmin but the prose
says "maximizes"; information gain is maximized here.)

Internally all objectives are NEGATED (paper metrics are minimized; MES wants
maximization), which the tuner handles before calling in here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gp import GPState, gp_joint_samples, gp_predict

__all__ = ["frontier_maxima", "mes_information_gain", "imoo_scores",
           "imoo_scores_batch"]


@functools.partial(jax.jit, static_argnames=("s",))
def frontier_maxima(state: GPState, cand: jnp.ndarray, key: jax.Array,
                    s: int = 10) -> jnp.ndarray:
    """Sample S Pareto frontiers via joint GP posterior draws over the
    candidate set and return the per-objective frontier maxima y*_s [S, m].

    For a maximization problem the per-objective maximum over the sampled
    Pareto set equals the per-objective maximum over the whole sample (the
    argmax point of objective i is never dominated in i), so no explicit
    dominance filtering is needed — this is the standard MESMO reduction.
    """
    samples = gp_joint_samples(state, cand, key, s=s)  # [S, q, m]
    return jnp.max(samples, axis=1)  # [S, m]


@jax.jit
def mes_information_gain(mean: jnp.ndarray, std: jnp.ndarray,
                         ystar: jnp.ndarray,
                         weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. (8)+(9): I(x') [q] from posterior (mean,std) [q,m] and y* [S,m].

    ``weights`` [m] (optional) scalarizes the per-objective information gain
    ``I(x') = Σ_i w_i·AF_i(x')`` — the fleet runner uses it to bias scenarios
    toward latency/power/area without touching the GP (target scaling would
    cancel under standardization). ``None`` ≡ uniform weights."""
    gamma = (ystar[:, None, :] - mean[None, :, :]) / std[None, :, :]  # [S,q,m]
    pdf = jax.scipy.stats.norm.pdf(gamma)
    cdf = jnp.clip(jax.scipy.stats.norm.cdf(gamma), 1e-9, 1.0)
    af = gamma * pdf / (2.0 * cdf) - jnp.log(cdf)  # [S, q, m]
    per_obj = jnp.mean(af, axis=0)  # (1/S) Σ_s — Eq. (7)
    if weights is not None:
        per_obj = per_obj * weights[None, :]
    return jnp.sum(per_obj, axis=-1)  # Σ_i — Eq. (9)


def imoo_scores(state: GPState, cand: jnp.ndarray, key: jax.Array,
                s: int = 10, frontier_cand: jnp.ndarray | None = None,
                weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Acquisition score for every candidate row (maximization convention).

    ``frontier_cand`` (default: ``cand``) is the subset used for the O(q³)
    joint frontier sampling; scoring itself is O(n·q) and runs on the full
    pool.
    """
    fc = cand if frontier_cand is None else frontier_cand
    ystar = frontier_maxima(state, fc, key, s=s)
    mean, std = gp_predict(state, cand)
    return mes_information_gain(mean, std, ystar, weights)


@functools.partial(jax.jit, static_argnames=("s",))
def imoo_scores_batch(states: GPState, cand: jnp.ndarray, keys: jax.Array,
                      s: int = 10, frontier_cand: jnp.ndarray | None = None,
                      weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """IMOO scores for ``S`` scenarios at once -> [S, N].

    ``states`` is a batched ``GPState`` from ``fit_gp_batch``; ``cand``
    [S,N,d] and ``frontier_cand`` [S,q,d] are per-scenario ICD pools; ``keys``
    [S,2] per-scenario PRNG keys; ``weights`` [S,m] optional per-scenario
    objective weightings. One vmapped XLA program covers the whole fleet's
    round — per-scenario math identical to :func:`imoo_scores`."""
    fc = cand if frontier_cand is None else frontier_cand

    def one(state, c, f, k, w):
        ystar = frontier_maxima(state, f, k, s=s)
        mean, std = gp_predict(state, c)
        return mes_information_gain(mean, std, ystar, w)

    if weights is None:
        return jax.vmap(lambda st, c, f, k: one(st, c, f, k, None))(
            states, cand, fc, keys)
    return jax.vmap(one)(states, cand, fc, keys, weights)
