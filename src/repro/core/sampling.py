"""Algorithm 2 — SoC-Init(X, u, b, v, v_th): importance-guided TED init.

Line 1 prunes (pins) unimportant features; line 2 maps the candidate pool to
ICD space ``x' = v ⊙ x``; lines 3-8 run Transductive Experimental Design
(Yu, Bi & Tresp, ICML'06) greedily: pick the point whose kernel column has the
largest energy, then deflate the kernel matrix with the rank-1 downdate.

The paper writes Φ(.) as "Euclidean distance"; TED's selection rule is only
meaningful on a *similarity* kernel (the diagonal of a distance matrix is 0,
which would make the normalizer constant and the downdate divide by µ alone).
As in BOOM-Explorer — the paper's own reference [9] for this component — we
build K as a Gaussian kernel over those Euclidean distances with a
median-heuristic bandwidth. Recorded in DESIGN.md §1 fidelity notes.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from .space import DesignSpace

__all__ = ["soc_init", "ted_select", "transform_to_icd", "median_bandwidth",
           "TED_MAX_POOL", "TED_CAP_STATS", "fold_ted_stats"]

#: Default TED candidate cap. The greedy TED loop is O(b·N²) time and O(N²)
#: memory (the deflated kernel matrix), which is fine at the paper's 2500-pool
#: scale but impossible at the 10⁵–10⁶ pools the chunked BO engine supports
#: (see docs/scaling.md). Above the cap, ``ted_select`` runs on an
#: even-stride subsample and maps the selection back; pools at or below the
#: cap take the historical path bit-for-bit.
TED_MAX_POOL = 4096

#: Host-side cap accounting (no-silent-caps house rule): every capped
#: ``ted_select`` call bumps ``capped_calls`` and adds the candidates the
#: even stride dropped to ``dropped_candidates``. Scrape into a metrics
#: registry with :func:`fold_ted_stats`; reset by assigning zeros (tests).
TED_CAP_STATS = {"capped_calls": 0, "dropped_candidates": 0}


def fold_ted_stats(registry) -> None:
    """Fold the (cumulative) TED cap counters into a
    :class:`repro.obs.MetricsRegistry` (duck-typed). Idempotence is the
    caller's job — fold once per finished run, like ``EngineStats``."""
    if TED_CAP_STATS["capped_calls"]:
        registry.counter(
            "ted_capped_calls_total",
            "ted_select calls that ran on the even-stride subsample",
        ).inc(TED_CAP_STATS["capped_calls"])
        registry.counter(
            "ted_dropped_candidates_total",
            "candidates excluded from TED by the max_pool stride cap",
        ).inc(TED_CAP_STATS["dropped_candidates"])


def transform_to_icd(space: DesignSpace, idx: jnp.ndarray, v: np.ndarray) -> jnp.ndarray:
    """Line 2: X' = { v ⊙ x } over normalized features (Fig. 3 transform).

    ``v`` is rescaled so max(v)=1: the paper's toy example moves unimportant
    features *closer* while keeping important ones in place; sum-normalized v
    would shrink every dimension with d=26 and break the GP's unit-scale
    priors."""
    v = np.asarray(v, dtype=np.float32)
    v = v / max(v.max(), 1e-12)
    return space.encode(idx) * jnp.asarray(v)[None, :]


def _median_bandwidth_from_sqdist(d2: jnp.ndarray) -> float:
    n = d2.shape[0]
    off = d2[jnp.triu_indices(n, 1)] if n > 1 else d2.reshape(-1)
    med = jnp.sqrt(jnp.maximum(jnp.median(off), 1e-12))
    return float(med)


def median_bandwidth(x: jnp.ndarray) -> float:
    """Median pairwise distance heuristic for the TED kernel bandwidth."""
    return _median_bandwidth_from_sqdist(pairwise_sqdist(x, x))


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    """‖a_i − b_j‖² via the unified pairdist backend (``kernels.backend``).

    Default dispatch is ``auto`` — XLA on every platform (bit-identical to
    the historical inline form) unless ``REPRO_PAIRDIST_BACKEND`` upgrades
    it to ``platform`` (Pallas on TPU); ``use_kernel=True`` forces the
    Pallas path (interpret-mode off-TPU) for kernel-parity sweeps."""
    from repro.kernels import backend as _backend

    return _backend.pairdist_auto(a, b,
                                  backend="pallas" if use_kernel else "auto")


@functools.partial(jax.jit, static_argnames=("b",))
def _ted_loop(K: jnp.ndarray, b: int, mu: float) -> jnp.ndarray:
    """Greedy TED: lines 4-8 of Algorithm 2, as a lax.fori_loop."""

    def body(_, carry):
        K, chosen, step = carry
        norm = jnp.sum(K * K, axis=0)  # ||K_x||² (column energy)
        score = norm / (jnp.diagonal(K) + mu)  # line 5
        # Mask already-chosen points.
        taken = jnp.zeros(K.shape[0], dtype=bool).at[chosen].set(True, mode="drop")
        score = jnp.where(taken, -jnp.inf, score)
        z = jnp.argmax(score)
        Kz = K[:, z]
        K = K - jnp.outer(Kz, Kz) / (K[z, z] + mu)  # line 7 downdate
        chosen = chosen.at[step].set(z)
        return K, chosen, step + 1

    # Sentinel = N (out of bounds) so the scatter with mode="drop" ignores
    # not-yet-chosen slots; -1 would wrap to the last row.
    chosen0 = jnp.full((b,), K.shape[0], dtype=jnp.int32)
    _, chosen, _ = jax.lax.fori_loop(0, b, body, (K, chosen0, 0))
    return chosen


def ted_select(x: jnp.ndarray, b: int, mu: float = 0.1,
               bandwidth: float | None = None,
               use_kernel: bool = False,
               max_pool: int | None = TED_MAX_POOL) -> np.ndarray:
    """Select ``b`` maximally informative rows of ``x`` [N, d] (TED).

    ``max_pool`` caps the O(N²) greedy loop: above it, selection runs on an
    even-stride subsample of ``max_pool`` rows (deterministic — no RNG
    plumbing, and an even stride of a uniformly drawn pool is itself
    uniform) and the chosen indices are mapped back to the full pool.
    ``max_pool=None`` opts out; the kernel build then streams through
    ``pairdist_chunked`` so at least the pairwise temporaries stay bounded
    (the [N, N] kernel matrix itself is unavoidable for the downdate loop).
    """
    N = x.shape[0]
    if max_pool is not None and N > max_pool:
        dropped = int(N) - int(max_pool)
        TED_CAP_STATS["capped_calls"] += 1
        TED_CAP_STATS["dropped_candidates"] += dropped
        warnings.warn(
            f"ted_select: pool of {N} exceeds max_pool={max_pool}; TED init "
            f"runs on an even-stride subsample, dropping {dropped} "
            "candidates from consideration (selection differs from the "
            "uncapped O(N²) run — pass max_pool=None to opt out)",
            stacklevel=2)
        sel = (np.arange(max_pool, dtype=np.int64) * N) // max_pool
        rows = ted_select(x[jnp.asarray(sel)], b, mu, bandwidth=bandwidth,
                          use_kernel=use_kernel, max_pool=None)
        return np.asarray(sel[rows])
    if N > TED_MAX_POOL and not use_kernel:
        from repro.kernels import backend as _backend

        d2 = _backend.pairdist_chunked(x, x, chunk=TED_MAX_POOL)
    else:
        d2 = pairwise_sqdist(x, x, use_kernel=use_kernel)
    if bandwidth is None:
        bandwidth = _median_bandwidth_from_sqdist(d2)  # reuse, don't recompute
    K = jnp.exp(-d2 / (2.0 * bandwidth**2 + 1e-12))
    return np.asarray(_ted_loop(K, b, float(mu)))


def soc_init(space: DesignSpace, pool_idx: np.ndarray, v: np.ndarray,
             v_th: float, b: int, mu: float = 0.1,
             use_kernel: bool = False,
             ted_pool: int | None = TED_MAX_POOL
             ) -> tuple[np.ndarray, DesignSpace, jnp.ndarray]:
    """Full Algorithm 2 over a candidate pool.

    Returns ``(init_rows, pruned_space, pool_icd)`` where ``init_rows`` indexes
    into ``pool_idx`` and ``pool_icd`` is the whole pool mapped to ICD space
    (reused by the tuner as the GP feature matrix). ``ted_pool`` caps the
    O(N²) TED selection on huge pools (see :func:`ted_select`); the ICD
    transform itself is elementwise and scales to 10⁶ rows unchanged.
    """
    pruned = space.prune(np.asarray(v), v_th)  # line 1
    pool_pruned = pruned.apply_pins(jnp.asarray(pool_idx))
    pool_icd = transform_to_icd(space, pool_pruned, v)  # line 2
    rows = ted_select(pool_icd, b=b, mu=mu, use_kernel=use_kernel,
                      max_pool=ted_pool)  # lines 3-8
    return rows, pruned, pool_icd
