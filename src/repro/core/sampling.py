"""Algorithm 2 — SoC-Init(X, u, b, v, v_th): importance-guided TED init.

Line 1 prunes (pins) unimportant features; line 2 maps the candidate pool to
ICD space ``x' = v ⊙ x``; lines 3-8 run Transductive Experimental Design
(Yu, Bi & Tresp, ICML'06) greedily: pick the point whose kernel column has the
largest energy, then deflate the kernel matrix with the rank-1 downdate.

The paper writes Φ(.) as "Euclidean distance"; TED's selection rule is only
meaningful on a *similarity* kernel (the diagonal of a distance matrix is 0,
which would make the normalizer constant and the downdate divide by µ alone).
As in BOOM-Explorer — the paper's own reference [9] for this component — we
build K as a Gaussian kernel over those Euclidean distances with a
median-heuristic bandwidth. Recorded in DESIGN.md §1 fidelity notes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .space import DesignSpace

__all__ = ["soc_init", "ted_select", "transform_to_icd", "median_bandwidth"]


def transform_to_icd(space: DesignSpace, idx: jnp.ndarray, v: np.ndarray) -> jnp.ndarray:
    """Line 2: X' = { v ⊙ x } over normalized features (Fig. 3 transform).

    ``v`` is rescaled so max(v)=1: the paper's toy example moves unimportant
    features *closer* while keeping important ones in place; sum-normalized v
    would shrink every dimension with d=26 and break the GP's unit-scale
    priors."""
    v = np.asarray(v, dtype=np.float32)
    v = v / max(v.max(), 1e-12)
    return space.encode(idx) * jnp.asarray(v)[None, :]


def _median_bandwidth_from_sqdist(d2: jnp.ndarray) -> float:
    n = d2.shape[0]
    off = d2[jnp.triu_indices(n, 1)] if n > 1 else d2.reshape(-1)
    med = jnp.sqrt(jnp.maximum(jnp.median(off), 1e-12))
    return float(med)


def median_bandwidth(x: jnp.ndarray) -> float:
    """Median pairwise distance heuristic for the TED kernel bandwidth."""
    return _median_bandwidth_from_sqdist(pairwise_sqdist(x, x))


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    """‖a_i − b_j‖² via the unified pairdist backend (``kernels.backend``).

    Default dispatch is ``auto`` — XLA on every platform (bit-identical to
    the historical inline form) unless ``REPRO_PAIRDIST_BACKEND`` upgrades
    it to ``platform`` (Pallas on TPU); ``use_kernel=True`` forces the
    Pallas path (interpret-mode off-TPU) for kernel-parity sweeps."""
    from repro.kernels import backend as _backend

    return _backend.pairdist_auto(a, b,
                                  backend="pallas" if use_kernel else "auto")


@functools.partial(jax.jit, static_argnames=("b",))
def _ted_loop(K: jnp.ndarray, b: int, mu: float) -> jnp.ndarray:
    """Greedy TED: lines 4-8 of Algorithm 2, as a lax.fori_loop."""

    def body(_, carry):
        K, chosen, step = carry
        norm = jnp.sum(K * K, axis=0)  # ||K_x||² (column energy)
        score = norm / (jnp.diagonal(K) + mu)  # line 5
        # Mask already-chosen points.
        taken = jnp.zeros(K.shape[0], dtype=bool).at[chosen].set(True, mode="drop")
        score = jnp.where(taken, -jnp.inf, score)
        z = jnp.argmax(score)
        Kz = K[:, z]
        K = K - jnp.outer(Kz, Kz) / (K[z, z] + mu)  # line 7 downdate
        chosen = chosen.at[step].set(z)
        return K, chosen, step + 1

    # Sentinel = N (out of bounds) so the scatter with mode="drop" ignores
    # not-yet-chosen slots; -1 would wrap to the last row.
    chosen0 = jnp.full((b,), K.shape[0], dtype=jnp.int32)
    _, chosen, _ = jax.lax.fori_loop(0, b, body, (K, chosen0, 0))
    return chosen


def ted_select(x: jnp.ndarray, b: int, mu: float = 0.1,
               bandwidth: float | None = None,
               use_kernel: bool = False) -> np.ndarray:
    """Select ``b`` maximally informative rows of ``x`` [N, d] (TED)."""
    d2 = pairwise_sqdist(x, x, use_kernel=use_kernel)
    if bandwidth is None:
        bandwidth = _median_bandwidth_from_sqdist(d2)  # reuse, don't recompute
    K = jnp.exp(-d2 / (2.0 * bandwidth**2 + 1e-12))
    return np.asarray(_ted_loop(K, b, float(mu)))


def soc_init(space: DesignSpace, pool_idx: np.ndarray, v: np.ndarray,
             v_th: float, b: int, mu: float = 0.1,
             use_kernel: bool = False) -> tuple[np.ndarray, DesignSpace, jnp.ndarray]:
    """Full Algorithm 2 over a candidate pool.

    Returns ``(init_rows, pruned_space, pool_icd)`` where ``init_rows`` indexes
    into ``pool_idx`` and ``pool_icd`` is the whole pool mapped to ICD space
    (reused by the tuner as the GP feature matrix).
    """
    pruned = space.prune(np.asarray(v), v_th)  # line 1
    pool_pruned = pruned.apply_pins(jnp.asarray(pool_idx))
    pool_icd = transform_to_icd(space, pool_pruned, v)  # line 2
    rows = ted_select(pool_icd, b=b, mu=mu, use_kernel=use_kernel)  # lines 3-8
    return rows, pruned, pool_icd
