"""Training substrate: optimizer (ZeRO-1), data, checkpoints, loop."""
from .optimizer import (TrainState, adamw_init, adamw_update, cosine_lr,
                        LRSchedule, tree_zero1_specs, zero1_spec)
from .data import DataConfig, make_batch, bigram_entropy
from .checkpoint import AsyncCheckpointer, latest_step, restore, save
from .loop import TrainConfig, make_train_step, train

__all__ = [
    "TrainState", "adamw_init", "adamw_update", "cosine_lr", "LRSchedule",
    "tree_zero1_specs", "zero1_spec",
    "DataConfig", "make_batch", "bigram_entropy",
    "AsyncCheckpointer", "latest_step", "restore", "save",
    "TrainConfig", "make_train_step", "train",
]
