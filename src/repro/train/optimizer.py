"""AdamW with f32 master weights and ZeRO-1 optimizer-state sharding.

The master params / first / second moments carry *additional* data-parallel
sharding on top of the tensor-parallel spec (``zero1_spec``): GSPMD then
derives the ZeRO-1 schedule automatically — gradients reduce-scatter into the
shard, the update runs shard-local, and the bf16 cast all-gathers for the
next forward. No hand-written collectives.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import AxisRules

__all__ = ["TrainState", "adamw_init", "adamw_update", "zero1_spec",
           "tree_zero1_specs", "LRSchedule", "cosine_lr"]


class TrainState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    params: Any        # f32 master weights
    m: Any             # first moment (f32)
    v: Any             # second moment (f32)


class LRSchedule(NamedTuple):
    base: float = 3e-4
    warmup: int = 100
    total: int = 10000
    min_ratio: float = 0.1


def cosine_lr(sched: LRSchedule, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32) + 1.0  # step 0 trains too
    warm = jnp.minimum(s / max(sched.warmup, 1), 1.0)
    prog = jnp.clip((s - sched.warmup) / max(sched.total - sched.warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return sched.base * warm * (sched.min_ratio + (1 - sched.min_ratio) * cos)


def adamw_init(params: Any) -> TrainState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(jnp.zeros((), jnp.int32), params, zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_update(state: TrainState, grads: Any, lr: jnp.ndarray,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 wd: float = 0.1, clip: float = 1.0) -> TrainState:
    # global-norm clip
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    t = state.step.astype(jnp.float32) + 1.0
    c1, c2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        return p - lr * (step + wd * p), m, v

    out = jax.tree.map(upd, state.params, grads, state.m, state.v)
    params = jax.tree.map(lambda t3: t3[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return TrainState(state.step + 1, params, m, v)


# ---------------------------------------------------------------- ZeRO-1
def zero1_spec(base: P, shape: tuple[int, ...], rules: AxisRules) -> P:
    """Add data-parallel sharding to the largest unsharded divisible dim."""
    if not rules.axis_sizes:
        return base
    dp_axes = tuple(a for a in ("pod", "data") if a in rules.axis_sizes)
    if not dp_axes:
        return base
    dp = 1
    for a in dp_axes:
        dp *= rules.axis_sizes[a]
    entries = list(base) + [None] * (len(shape) - len(base))
    taken = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                taken.add(a)
    if any(a in taken for a in dp_axes):
        return base
    # largest unsharded divisible dim gets the dp axes
    cand = [(shape[i], i) for i in range(len(shape))
            if entries[i] is None and shape[i] % dp == 0 and shape[i] >= dp]
    if not cand:
        return base
    _, i = max(cand)
    entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_zero1_specs(axes_tree: Any, params: Any, rules: AxisRules) -> Any:
    """PartitionSpec tree for master/m/v with ZeRO-1 data sharding."""
    def one(axes, leaf):
        base = rules.spec(axes, leaf.shape)
        return zero1_spec(base, tuple(leaf.shape), rules)

    return jax.tree.map(
        one, axes_tree, params,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t))
