"""Deterministic, stateless synthetic data pipeline.

Batch ``k`` is a pure function of ``(seed, k)`` — a counter-based threefry
stream — so there is *no pipeline state to checkpoint or lose*: after a
restart (or an elastic re-shard to a different host count) batch ``k`` is
regenerated bit-exactly from ``k`` alone. Per-host slices are derived by
folding in the host id, so no host-0 broadcast sits on the hot path
(straggler mitigation: every host computes its shard independently).

Data is a fixed random **bigram language** (each token has ``branch``
successors with Zipf-ish weights): unlearnable noise would keep CE at ln(V),
whereas a bigram source gives training curves a real signal to descend to
the bigram entropy — which the example driver asserts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "make_batch", "bigram_entropy"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int            # tokens per example, +1 for the label shift
    global_batch: int
    seed: int = 0
    branch: int = 4         # successors per token


def _succ_table(cfg: DataConfig) -> jnp.ndarray:
    """[V, branch] fixed successor table (derived from the seed)."""
    key = jax.random.PRNGKey(cfg.seed)
    return jax.random.randint(key, (cfg.vocab, cfg.branch), 0, cfg.vocab)


def _branch_probs(cfg: DataConfig) -> jnp.ndarray:
    w = 1.0 / (1.0 + jnp.arange(cfg.branch, dtype=jnp.float32))  # Zipf-ish
    return w / w.sum()


def make_batch(cfg: DataConfig, step: int | jnp.ndarray,
               host_id: int = 0, n_hosts: int = 1) -> dict:
    """Tokens [B/n_hosts, seq_len+1] for this host at this step."""
    assert cfg.global_batch % n_hosts == 0
    b = cfg.global_batch // n_hosts
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(cfg.seed + 1), jnp.asarray(step)), host_id)
    k0, k1, k2 = jax.random.split(key, 3)
    succ = _succ_table(cfg)
    probs = _branch_probs(cfg)
    first = jax.random.randint(k0, (b,), 0, cfg.vocab)
    choices = jax.random.choice(k1, cfg.branch, shape=(b, cfg.seq_len),
                                p=probs)

    def step_fn(cur, ch):
        nxt = succ[cur, ch]
        return nxt, nxt

    _, rest = jax.lax.scan(step_fn, first, choices.T)
    tokens = jnp.concatenate([first[:, None], rest.T], axis=1)
    return {"tokens": tokens.astype(jnp.int32)}


def bigram_entropy(cfg: DataConfig) -> float:
    """Entropy of the generating bigram distribution (the CE floor)."""
    p = _branch_probs(cfg)
    return float(-jnp.sum(p * jnp.log(p)))
