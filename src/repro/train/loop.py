"""Training step + fault-tolerant loop.

``make_train_step`` builds one jit-able (state, batch) -> (state, metrics)
program: microbatched gradient accumulation via ``lax.scan`` (the per-
microbatch psum overlaps the next microbatch's compute — XLA async
collectives), optional int8 gradient compression with error feedback on the
``pod`` axis, grads constrained to the ZeRO-1 specs (=> reduce-scatter), and
the AdamW shard-local update.

``train`` is the driver: checkpoint/restart (async writer), preemption
drills (``preempt_after`` raises mid-run exactly like a SIGTERM handler
would), bit-exact resume (counter-based data pipeline), straggler-free batch
derivation (each host computes its own slice).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.parallel.collectives import ef_update, init_error_feedback
from repro.parallel.sharding import current_rules
from .checkpoint import AsyncCheckpointer, latest_step, restore
from .data import DataConfig, make_batch
from .optimizer import (LRSchedule, TrainState, adamw_init, adamw_update,
                        cosine_lr, tree_zero1_specs)

__all__ = ["TrainConfig", "make_train_step", "train"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatch: int = 0          # micro-batches per step (0/1 = none)
    lr: LRSchedule = LRSchedule()
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_grads: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10


def _cast_bf16(params: Any) -> Any:
    return jax.tree.map(lambda p: p.astype(jnp.bfloat16)
                        if p.dtype == jnp.float32 and p.ndim > 1 else p, params)


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(a is None or isinstance(a, str)
                                        for a in t)


def _constrain_compute_copy(p_bf: Any, axes_tree: Any) -> Any:
    """Pin the bf16 compute copy to tensor-parallel-only sharding (no ZeRO
    *and no FSDP dim*). Two measured failure modes without this (§Perf
    iterations 3/6, minicpm3 train_4k): (a) propagation pushes the master's
    data-sharded layout into the microbatch scan and weights re-gather per
    microbatch per remat segment; (b) worse, XLA keeps the FSDP weight shard
    and computes dots with a *contracted sharded dim*, all-reducing a full
    activation tensor per layer. Gathered once per step out here, both
    disappear; the bf16 copy costs model-sharded + replicated-attention
    memory only."""
    r = current_rules()
    if r.mesh is None or axes_tree is None:
        return p_bf
    from repro.parallel.sharding import AxisRules
    plain = AxisRules(r.mesh, dict(r.rules, embed_fsdp=()))
    return jax.tree.map(
        lambda axes, x: jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(r.mesh, plain.spec(axes, x.shape))),
        axes_tree, p_bf, is_leaf=_is_axes)


def make_train_step(cfg, tcfg: TrainConfig, axes_tree: Any = None):
    """Returns ``step_fn(state, batch, ef) -> (state, ef, metrics)``.

    ``ef`` is the error-feedback residual tree (zeros when compression off —
    kept in the signature so the jit program is stable either way).
    """
    def step_fn(state: TrainState, batch: dict, ef: Any):
        p_bf = _constrain_compute_copy(_cast_bf16(state.params), axes_tree)

        def loss_of(p, mb):
            loss, metrics = loss_fn(p, cfg, mb)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_of, has_aux=True)

        if tcfg.microbatch and tcfg.microbatch > 1:
            n = tcfg.microbatch
            mb_batch = jax.tree.map(
                lambda t: t.reshape((n, t.shape[0] // n) + t.shape[1:]), batch)

            # Accumulate into the ZeRO (data-sharded) layout: each
            # microbatch's cross-data gradient sum lowers to a
            # reduce-scatter (1x bytes) instead of a ring all-reduce into a
            # replicated accumulator (2x bytes) — §Perf iteration 4.
            r = current_rules()
            acc_con = (lambda t: t)
            if r.mesh is not None and axes_tree is not None:
                specs = tree_zero1_specs(axes_tree, p_bf, r)
                acc_con = lambda t: jax.tree.map(  # noqa: E731
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, jax.sharding.NamedSharding(r.mesh, s)), t, specs)

            def micro(acc, mb):
                (loss, metrics), g = grad_fn(p_bf, mb)
                acc = acc_con(jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / n, acc, g))
                return acc, (loss, metrics)

            zeros = acc_con(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), p_bf))
            grads, (losses, metricses) = jax.lax.scan(micro, zeros, mb_batch)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricses)
        else:
            (loss, metrics), grads = grad_fn(p_bf, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        if tcfg.compress_grads:
            grads, ef = ef_update(grads, ef)

        # constrain grads to the ZeRO-1 (data-sharded) opt-state layout:
        # GSPMD turns this into a reduce-scatter instead of all-reduce.
        r = current_rules()
        if r.mesh is not None and axes_tree is not None:
            specs = tree_zero1_specs(axes_tree, grads, r)
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, jax.sharding.NamedSharding(r.mesh, s)), grads, specs)

        lr = cosine_lr(tcfg.lr, state.step)
        state = adamw_update(state, grads, lr, wd=tcfg.weight_decay,
                             clip=tcfg.grad_clip)
        metrics = dict(metrics, loss=loss, lr=lr)
        return state, ef, metrics

    return step_fn


def train(cfg, tcfg: TrainConfig, data_cfg: DataConfig,
          init_params_fn: Callable[[], tuple[Any, Any]],
          preempt_after: Optional[int] = None,
          verbose: bool = True) -> tuple[TrainState, list[dict]]:
    """Fault-tolerant driver. Resumes from ``tcfg.ckpt_dir`` when present."""
    params, axes_tree = init_params_fn()
    state = adamw_init(params)
    ef = init_error_feedback(params) if tcfg.compress_grads else \
        jax.tree.map(lambda _: jnp.zeros((), jnp.float32), params)
    start = 0
    ck = AsyncCheckpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None

    if tcfg.ckpt_dir and latest_step(tcfg.ckpt_dir) is not None:
        state, manifest = restore(tcfg.ckpt_dir, state)
        start = int(manifest["step"])
        if verbose:
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, tcfg, axes_tree), donate_argnums=(0,))
    history: list[dict] = []
    t0 = time.time()
    try:
        for k in range(start, tcfg.steps):
            batch = make_batch(data_cfg, k)
            state, ef, metrics = step_fn(state, batch, ef)
            if preempt_after is not None and k + 1 >= preempt_after:
                raise KeyboardInterrupt(f"simulated preemption at step {k + 1}")
            if (k + 1) % tcfg.log_every == 0 or k + 1 == tcfg.steps:
                rec = {"step": k + 1,
                       **{kk: float(vv) for kk, vv in metrics.items()},
                       "wall_s": time.time() - t0}
                history.append(rec)
                if verbose:
                    print(f"[train] step {rec['step']:5d} "
                          f"loss={rec['loss']:.4f} lr={rec['lr']:.2e}")
            if ck and (k + 1) % tcfg.ckpt_every == 0:
                ck.submit(k + 1, state)
    except KeyboardInterrupt:
        if ck:
            ck.submit(int(state.step), state)
            ck.wait()
        if verbose:
            print(f"[train] preempted at step {int(state.step)}; "
                  f"checkpoint written")
        return state, history
    if ck:
        ck.submit(tcfg.steps, state)
        ck.wait()
    return state, history
