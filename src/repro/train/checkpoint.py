"""Sharded checkpoints with manifest, atomic rename, async write, and
**elastic restore** (re-shard onto a different mesh / device count).

Layout:  <dir>/step_<k>/arrays.npz + manifest.json ; <dir>/LATEST is updated
by atomic rename *after* the payload is durable, so a crash mid-write never
corrupts the restore point (the previous step stays live). ``restore`` takes
an optional ``sharding_tree``: arrays are ``device_put`` against the *new*
mesh, which is all ZeRO/TP re-sharding amounts to with a counter-based data
pipeline (no dataloader state, no optimizer realignment).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = _SEP.join(_key_str(k) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # npz has no bf16; upcast lossless
        flat[name] = arr
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Durable checkpoint write: tmp dir -> fsync -> atomic rename."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory)
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": int(step),
            "names": sorted(flat),
            "treedef": str(jax.tree_util.tree_structure(tree)),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(directory, ".LATEST_tmp"), "w") as f:
        f.write(f"step_{step:08d}")
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, ".LATEST_tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    marker = os.path.join(directory, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore(directory: str, template: Any, step: Optional[int] = None,
            sharding_tree: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template``. ``sharding_tree`` (same
    structure, NamedSharding leaves or None) re-shards elastically onto the
    current mesh — a checkpoint written on N chips restores on M chips."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    shard_leaves = (jax.tree.leaves(sharding_tree, is_leaf=lambda x: x is None)
                    if sharding_tree is not None else [None] * len(leaves_paths))
    for (path_k, leaf), shard in zip(leaves_paths, shard_leaves):
        name = _SEP.join(_key_str(k) for k in path_k)
        arr = arrays[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: ckpt shape {arr.shape} != {leaf.shape}")
        arr = jax.numpy.asarray(arr).astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class AsyncCheckpointer:
    """Background-thread writer: the train loop hands off host copies and
    keeps stepping; ``wait()`` joins before exit. One in-flight checkpoint at
    a time (the common orbax discipline)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    def submit(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation

        def run():
            try:
                save(self.directory, step, host_tree, extra)
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err
